"""Mixed-precision Krylov engine: fp32 inner cycles + fp64 iterative
refinement vs the all-fp64 baseline (the precision-policy tentpole).

Both sides run the SAME lockstep batched engine over the same sorted,
chunk-decomposed sequence (one recycle carry per chunk); the fp32 side sets
`KrylovConfig.inner_dtype="float32"`, which moves every bandwidth-bound
inner dispatch — Arnoldi cycles (DIA/stencil SpMV + CGS2 against the
(m+1, n) basis), preconditioner applies, recycle-space updates — to half
the HBM traffic while an fp64 outer loop replays the TRUE residual until
`tol`. Reported per family: wall-clock, total iterations, and the max
final fp64 relative residual of each side (the accuracy-parity check: both
must sit at ≤ tol — dataset labels keep full tolerance).

The steady families time the solver loop only (operators pre-assembled —
the quantity under test is solve throughput); the `heat` row runs the full
time-dependent trajectory engine end to end (recycling across time steps).

Run:  PYTHONPATH=src python -m benchmarks.mixed_precision [--quick]
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CSV
from repro.core.sorting import sort_features
from repro.core.trajectory import TrajConfig, generate_trajectories_chunked
from repro.pde.dia import Stencil5
from repro.pde.registry import get_family, get_timedep_family
from repro.solvers.batched import BatchedGCRODRSolver
from repro.solvers.operator import PreconditionedOp, StencilOp
from repro.solvers.precond import make_preconditioner_batched
from repro.solvers.types import KrylovConfig

TOL = 1e-6
SPEEDUP_TARGET = 1.5   # acceptance: ≥ this on at least one family


def _steady_case(family: str, nx: int, num: int, workers: int,
                 kc: KrylovConfig, precond: str = "jacobi"):
    """Pre-assembled sorted/chunked lockstep solve; returns a closure that
    runs one full pass with a given config and reports (wall, iters, res)."""
    fam = get_family(family, nx=nx, ny=nx)
    batch = fam.sample_batch(jax.random.PRNGKey(0), num)
    order = sort_features(np.asarray(batch.features), "greedy")
    bounds = np.linspace(0, num, workers + 1).astype(int)
    subs = [order[bounds[w]: bounds[w + 1]] for w in range(workers)]
    rows = max(len(s) for s in subs)
    all5 = Stencil5(jnp.asarray(batch.op.coeffs))
    b_all = np.asarray(batch.b).reshape(num, -1)

    def run_once(cfg: KrylovConfig):
        solver = BatchedGCRODRSolver(cfg)
        iters, maxres, conv = 0, 0.0, 0
        for t in range(rows):
            idx = np.array([int(s[t]) if t < len(s) else -1 for s in subs])
            st5 = all5.take(jnp.asarray(np.where(idx >= 0, idx, 0)))
            pre = make_preconditioner_batched(precond, st5)
            ops = PreconditionedOp(StencilOp(st5.coeffs), pre)
            bvec = b_all[np.where(idx >= 0, idx, 0)].copy()
            bvec[idx < 0] = 0.0
            _, sts = solver.solve_batch(ops, jnp.asarray(bvec))
            for w, i in enumerate(idx):
                if i < 0:
                    continue
                iters += sts[w].iterations
                maxres = max(maxres, sts[w].rel_residual)
                conv += int(sts[w].converged)
        return iters, maxres, conv

    def timed(cfg: KrylovConfig):
        run_once(cfg)               # warmup: compile every dispatch
        t0 = time.perf_counter()
        iters, maxres, conv = run_once(cfg)
        return time.perf_counter() - t0, iters, maxres, conv

    return timed


def _heat_case(nx: int, num: int, nt: int, workers: int, kc: KrylovConfig):
    """Full trajectory-engine pass on the `heat` family (recycling across
    time steps, lockstep over chunks of trajectories)."""
    fam = get_timedep_family("heat", nx=nx, ny=nx, nt=nt)

    def timed(cfg: KrylovConfig):
        tcfg = TrajConfig(krylov=cfg, precond="jacobi")
        generate_trajectories_chunked(fam, jax.random.PRNGKey(1), num, tcfg,
                                      workers=workers)  # warmup
        t0 = time.perf_counter()
        chunks = generate_trajectories_chunked(fam, jax.random.PRNGKey(0),
                                               num, tcfg, workers=workers)
        wall = time.perf_counter() - t0
        iters = sum(c.stats.total_iterations for c in chunks)
        maxres = max((s.rel_residual for c in chunks
                      for s in c.stats.per_system), default=0.0)
        conv = sum(c.stats.num_converged for c in chunks)
        return wall, iters, maxres, conv

    return timed


def run(quick: bool = False):
    kc = KrylovConfig(m=30, k=10, tol=TOL, maxiter=20_000)
    kc32 = dataclasses.replace(kc, inner_dtype="float32")
    if quick:
        cases = [
            ("poisson", _steady_case("poisson", 96, 8, 4, kc)),
            ("darcy", _steady_case("darcy", 96, 8, 4, kc)),
            ("helmholtz", _steady_case("helmholtz", 32, 8, 4, kc)),
            ("heat", _heat_case(48, 8, 6, 4, kc)),
        ]
    else:
        cases = [
            ("poisson", _steady_case("poisson", 96, 16, 8, kc)),
            ("darcy", _steady_case("darcy", 96, 16, 8, kc)),
            ("helmholtz", _steady_case("helmholtz", 48, 16, 8, kc)),
            ("heat", _heat_case(32, 12, 8, 4, kc)),
        ]

    csv = CSV(["family", "inner_dtype", "wall_s", "iters", "max_rel_res",
               "converged", "speedup"])
    metrics = {}
    for family, timed in cases:
        w64, i64, r64, c64 = timed(kc)
        w32, i32, r32, c32 = timed(kc32)
        sp = w64 / w32
        csv.row(family, "float64", f"{w64:.3f}", i64, f"{r64:.2e}", c64, "-")
        csv.row(family, "float32", f"{w32:.3f}", i32, f"{r32:.2e}", c32,
                f"{sp:.2f}x")
        metrics[family] = {
            "wall_s_f64": round(w64, 3), "wall_s_f32": round(w32, 3),
            "iters_f64": i64, "iters_f32": i32,
            "max_rel_res_f64": r64, "max_rel_res_f32": r32,
            "converged_f64": c64, "converged_f32": c32,
            "speedup": round(sp, 3),
        }
    csv.emit(f"fp32-inner + fp64 refinement vs fp64 baseline "
             f"(lockstep engine, tol {TOL:g})")
    best = max(metrics.values(), key=lambda m: m["speedup"])
    for family, m in metrics.items():
        ok = m["speedup"] >= SPEEDUP_TARGET
        acc = m["max_rel_res_f32"] <= TOL
        print(f"  {family}: fp32-inner {m['speedup']:.2f}x "
              f"[{'OK' if ok else 'below target'}] "
              f"accuracy {'EQUAL (<= tol)' if acc else 'DEGRADED'}")
    print(f"  best speedup {best['speedup']:.2f}x "
          f"(target >= {SPEEDUP_TARGET}x on at least one family): "
          f"{'PASS' if best['speedup'] >= SPEEDUP_TARGET else 'FAIL'}")
    metrics["speedup_target"] = SPEEDUP_TARGET
    metrics["best_speedup"] = best["speedup"]
    # acceptance gate — benchmarks/run.py exits nonzero when ok=False, so
    # the CI bench job actually fails on a speedup/accuracy regression
    metrics["ok"] = bool(
        best["speedup"] >= SPEEDUP_TARGET
        and all(m["max_rel_res_f32"] <= TOL for m in metrics.values()
                if isinstance(m, dict) and "max_rel_res_f32" in m))
    return metrics


if __name__ == "__main__":
    import sys

    run(quick="--quick" in sys.argv)
