"""Shared benchmark scaffolding: timed SKR-vs-GMRES dataset runs and CSV
emission. Scales are CPU-sized (paper's full 72-thread Xeon runs are out of
scope for this box) — speedup RATIOS are the reproduced quantity."""
from __future__ import annotations

import dataclasses
import io
import time
from typing import List, Optional

import jax
import numpy as np

from repro.core.skr import SKRConfig, SKRGenerator
from repro.pde.registry import get_family
from repro.solvers.types import KrylovConfig


@dataclasses.dataclass
class RunResult:
    name: str
    mean_time_s: float
    mean_iters: float
    hit_maxiter: int
    num: int
    extra: Optional[dict] = None


def run_sequence(family_name: str, *, nx: int, num: int, tol: float,
                 precond: str, solver: str, m: int = 40, k: int = 15,
                 maxiter: int = 10_000, sort_method: str = "greedy",
                 seed: int = 0, warmup: int = 1):
    """One (dataset × precond × tol × solver) cell. `solver` ∈ {skr, gmres}.
    A warmup solve triggers all JIT compiles before timing starts."""
    fam = get_family(family_name, nx=nx, ny=nx)
    if solver == "gmres":
        cfg = SKRConfig(krylov=KrylovConfig(m=m, k=0, tol=tol,
                                            maxiter=maxiter),
                        sort_method="none", precond=precond)
    else:
        cfg = SKRConfig(krylov=KrylovConfig(m=m, k=k, tol=tol,
                                            maxiter=maxiter),
                        sort_method=sort_method, precond=precond)
    gen = SKRGenerator(fam, cfg)
    if warmup:
        gen.generate(jax.random.PRNGKey(seed + 999), warmup)
    t0 = time.perf_counter()
    res = gen.generate(jax.random.PRNGKey(seed), num)
    wall = time.perf_counter() - t0
    s = res.stats
    return res, RunResult(
        name=f"{family_name}/{precond}/{tol:g}/{solver}",
        mean_time_s=wall / num,
        mean_iters=s.mean_iterations,
        hit_maxiter=s.num_hit_maxiter,
        num=num,
    )


class CSV:
    def __init__(self, header: List[str]):
        self.buf = io.StringIO()
        self.header = header
        print(",".join(header), file=self.buf)

    def row(self, *vals):
        print(",".join(str(v) for v in vals), file=self.buf)

    def emit(self, title: str):
        print(f"\n### {title}")
        print(self.buf.getvalue().rstrip())
