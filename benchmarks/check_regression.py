"""CI perf ratchet for the lockstep engine.

Compares a FRESH run of `trajectory_recycle` — in the SAME mode
(quick/full) as the committed baseline, so the ratio comparison is
apples-to-apples — against the committed
`results/BENCH_trajectory_recycle.json` artifact (the per-PR perf record):
the heat-family lockstep-vs-chunked-sequential wall-time ratio must stay
within REGRESSION_FACTOR of the committed value, and the lockstep engine
must hold its ≤ 1 blocking host sync per cycle budget. A PR that slows the
device-resident cycle path back toward host-mediated dispatch overhead
fails CI here instead of shipping as an unnoticed wall-time regression.

Also ratchets the label-expansion stage (benchmarks/label_expansion.py)
against `results/BENCH_label_expansion.json`: the worst-family K=8
labels/s ratio must stay within the same REGRESSION_FACTOR.

And the streaming scheduler (benchmarks/streaming_datagen.py) against
`results/BENCH_streaming_datagen.json`: the worst-family mid-flight
lockstep utilization must stay above 0.8x the committed value — a change
that lets retired slots ride as padding again (or stalls admission) shows
up here as live-row fraction collapsing toward the wave baseline.

The committed baseline is read BEFORE the fresh run (the bench harness
overwrites the same artifact path), so this module must be the one to
launch the bench — run it stand-alone:

    PYTHONPATH=src python -m benchmarks.check_regression
"""
from __future__ import annotations

import json
import os
import sys

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")
BASELINE = os.path.join(RESULTS, "BENCH_trajectory_recycle.json")
EXPAND_BASELINE = os.path.join(RESULTS, "BENCH_label_expansion.json")
STREAM_BASELINE = os.path.join(RESULTS, "BENCH_streaming_datagen.json")

# CI runners are noisy shared VMs: allow the ratio to dip to 75% of the
# committed value before calling it a regression (same slack philosophy as
# the coverage ratchet — tight enough to catch a host-boundary reintroduction
# splitting the cycle back into many dispatches, loose enough for jitter).
REGRESSION_FACTOR = 0.75
SYNC_BUDGET = 1.0  # blocking host fetches per lockstep cycle (inside loop)
# lockstep row utilization (live / total dispatched rows) must stay above
# this floor — padding creep in the chunk packing silently burns device
# time on zero-RHS rows. The quick bench's chains divide evenly (no
# padding → 1.0), so 0.8 has comfortable slack while still catching a
# packing regression; it is also the ROADMAP's streaming-scheduler target.
UTILIZATION_FLOOR = 0.8
# containment is ON by default (TrajConfig.retry = RetryPolicy()): on the
# healthy path its extra work is an all-False quarantine mask folded into the
# existing per-cycle flag fetch, so the heat lockstep solve with containment
# must stay within 5% of the retry=None wall time. The absolute slack keeps
# the relative gate meaningful on the quick bench's sub-second walls, where
# 5% of t_off is below CI timer noise.
CONTAIN_OVERHEAD_FACTOR = 1.05
CONTAIN_ABS_SLACK_S = 0.10


def containment_overhead() -> bool:
    """Min-of-3 heat lockstep wall with containment ON vs retry=None."""
    import dataclasses
    import time

    import jax

    from repro.core.robust import RetryPolicy
    from repro.core.trajectory import TrajConfig, generate_trajectories_chunked
    from repro.pde.registry import get_timedep_family
    from repro.solvers.types import KrylovConfig

    fam = get_timedep_family("heat", nx=14, ny=14, nt=6, dt=5e-2)
    key = jax.random.PRNGKey(0)
    kc = KrylovConfig(m=30, k=10, tol=1e-8, maxiter=10_000)
    base = TrajConfig(krylov=kc, sort_method="greedy", precond="jacobi")

    def wall(cfg):
        args = (fam, key, 4, cfg)
        generate_trajectories_chunked(*args, workers=2, engine="batched")
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            generate_trajectories_chunked(*args, workers=2, engine="batched")
            best = min(best, time.perf_counter() - t0)
        return best

    t_off = wall(dataclasses.replace(base, retry=None))
    t_on = wall(dataclasses.replace(base, retry=RetryPolicy()))
    limit = CONTAIN_OVERHEAD_FACTOR * t_off + CONTAIN_ABS_SLACK_S
    print(f"[check_regression] heat lockstep containment overhead: "
          f"{t_on:.3f}s on vs {t_off:.3f}s off (limit {limit:.3f}s)")
    if t_on > limit:
        print("[check_regression] FAIL: healthy-path containment overhead "
              f"exceeds {CONTAIN_OVERHEAD_FACTOR - 1:.0%} of the retry=None "
              "wall — the quarantine masking leaked work onto the hot path")
        return False
    return True


def label_expansion_ratchet() -> bool:
    """Labels/s ratchet for the expansion stage (benchmarks/
    label_expansion.py): the fresh worst-family K=8 labels/s ratio must
    stay within REGRESSION_FACTOR of the committed artifact's. A change
    that sneaks a host sync, a recompile, or a per-label dispatch back
    into the expansion wave shows up here as the ratio collapsing toward
    1x. The fresh run skips the FNO quality gates (gates=False) — those
    are validated when the artifact is (re)committed, not per CI run —
    but matches the committed quick/full mode for the throughput cells."""
    if not os.path.exists(EXPAND_BASELINE):
        print("[check_regression] no label_expansion baseline committed; "
              "skipping labels/s ratchet")
        return True
    with open(EXPAND_BASELINE) as f:
        doc = json.load(f)
    fams = [k for k, v in doc["metrics"].items()
            if isinstance(v, dict) and "k8_ratio" in v]
    committed = min(doc["metrics"][k]["k8_ratio"] for k in fams)
    floor = REGRESSION_FACTOR * committed

    from benchmarks import label_expansion
    fresh_doc = label_expansion.run(quick=bool(doc.get("quick")),
                                    gates=False)
    fresh = min(fresh_doc[k]["k8_ratio"] for k in fams)

    print(f"[check_regression] label expansion worst-family K=8 labels/s "
          f"ratio: fresh {fresh:.2f}x vs committed {committed:.2f}x "
          f"(floor {floor:.2f}x)")
    if fresh < floor:
        print("[check_regression] FAIL: label-expansion throughput "
              f"regressed below {REGRESSION_FACTOR:.0%} of the committed "
              "baseline — per-label cost crept back toward per-solve cost")
        return False
    return True


def streaming_ratchet() -> bool:
    """Mid-flight streaming utilization ratchet (benchmarks/
    streaming_datagen.py): the fresh worst-family `midflight.utilization`
    must stay above 0.8x the committed artifact's. The bench's own `ok`
    gate (absolute > 0.8, beats the wave baseline, label parity) rides
    along — a fresh run that fails its acceptance fails the ratchet."""
    if not os.path.exists(STREAM_BASELINE):
        print("[check_regression] no streaming_datagen baseline committed; "
              "skipping utilization ratchet")
        return True
    with open(STREAM_BASELINE) as f:
        doc = json.load(f)
    fams = [k for k, v in doc["metrics"].items()
            if isinstance(v, dict) and "midflight" in v]
    committed = min(doc["metrics"][k]["midflight"]["utilization"]
                    for k in fams)
    floor = 0.8 * committed

    from benchmarks import streaming_datagen
    fresh_doc = streaming_datagen.run(quick=bool(doc.get("quick")))
    fresh = min(fresh_doc[k]["midflight"]["utilization"] for k in fams)

    print(f"[check_regression] streaming worst-family mid-flight "
          f"utilization: fresh {fresh:.3f} vs committed {committed:.3f} "
          f"(floor {floor:.3f})")
    ok = True
    if fresh < floor:
        print("[check_regression] FAIL: streaming utilization regressed "
              "below 0.8x the committed baseline — retired slots are "
              "riding as padding again")
        ok = False
    if not fresh_doc.get("ok"):
        print("[check_regression] FAIL: streaming_datagen acceptance gate "
              "(absolute utilization / wave gap / label parity) failed on "
              "the fresh run")
        ok = False
    return ok


def main() -> int:
    with open(BASELINE) as f:
        doc = json.load(f)
    committed = doc["metrics"]["heat"]["lockstep_speedup"]
    # match the committed artifact's mode: a quick fresh run measured
    # against a full-run baseline compares different problem sizes (the
    # lockstep advantage grows with n), which is not a regression signal
    quick = bool(doc.get("quick"))
    floor = REGRESSION_FACTOR * committed

    from benchmarks import trajectory_recycle
    summary = trajectory_recycle.run(quick=quick)
    heat = summary["heat"]
    fresh = heat["lockstep_speedup"]
    syncs = heat["lockstep_syncs_per_cycle"]
    # optional key: artifacts/summaries written before the telemetry layer
    # landed don't carry it — treat absence as "not checked", not a failure
    util = heat.get("lockstep_utilization")

    mode = "quick" if quick else "full"
    print(f"[check_regression] heat lockstep_speedup ({mode} mode): "
          f"fresh {fresh:.3f}x vs committed {committed:.3f}x "
          f"(floor {floor:.3f}x)")
    print(f"[check_regression] lockstep host syncs/cycle: {syncs:.2f} "
          f"(budget {SYNC_BUDGET:g})")
    if util is not None:
        print(f"[check_regression] lockstep row utilization: {util:.2f} "
              f"(floor {UTILIZATION_FLOOR:g})")

    ok = True
    if fresh < floor:
        print("[check_regression] FAIL: lockstep speedup regressed below "
              f"{REGRESSION_FACTOR:.0%} of the committed baseline")
        ok = False
    if syncs > SYNC_BUDGET:
        print("[check_regression] FAIL: lockstep cycle loop exceeds "
              "1 blocking host sync per cycle")
        ok = False
    if util is not None and util < UTILIZATION_FLOOR:
        print("[check_regression] FAIL: lockstep row utilization fell "
              f"below {UTILIZATION_FLOOR:g} — padding creep in the chunk "
              "packing")
        ok = False
    if not containment_overhead():
        ok = False
    if not label_expansion_ratchet():
        ok = False
    if not streaming_ratchet():
        ok = False
    if ok:
        print("[check_regression] OK")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
