"""CI perf ratchet for the lockstep engine.

Compares a FRESH run of `trajectory_recycle` — in the SAME mode
(quick/full) as the committed baseline, so the ratio comparison is
apples-to-apples — against the committed
`results/BENCH_trajectory_recycle.json` artifact (the per-PR perf record):
the heat-family lockstep-vs-chunked-sequential wall-time ratio must stay
within REGRESSION_FACTOR of the committed value, and the lockstep engine
must hold its ≤ 1 blocking host sync per cycle budget. A PR that slows the
device-resident cycle path back toward host-mediated dispatch overhead
fails CI here instead of shipping as an unnoticed wall-time regression.

The committed baseline is read BEFORE the fresh run (the bench harness
overwrites the same artifact path), so this module must be the one to
launch the bench — run it stand-alone:

    PYTHONPATH=src python -m benchmarks.check_regression
"""
from __future__ import annotations

import json
import os
import sys

BASELINE = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "BENCH_trajectory_recycle.json")

# CI runners are noisy shared VMs: allow the ratio to dip to 75% of the
# committed value before calling it a regression (same slack philosophy as
# the coverage ratchet — tight enough to catch a host-boundary reintroduction
# splitting the cycle back into many dispatches, loose enough for jitter).
REGRESSION_FACTOR = 0.75
SYNC_BUDGET = 1.0  # blocking host fetches per lockstep cycle (inside loop)
# lockstep row utilization (live / total dispatched rows) must stay above
# this floor — padding creep in the chunk packing silently burns device
# time on zero-RHS rows. The quick bench's chains divide evenly (no
# padding → 1.0), so 0.8 has comfortable slack while still catching a
# packing regression; it is also the ROADMAP's streaming-scheduler target.
UTILIZATION_FLOOR = 0.8


def main() -> int:
    with open(BASELINE) as f:
        doc = json.load(f)
    committed = doc["metrics"]["heat"]["lockstep_speedup"]
    # match the committed artifact's mode: a quick fresh run measured
    # against a full-run baseline compares different problem sizes (the
    # lockstep advantage grows with n), which is not a regression signal
    quick = bool(doc.get("quick"))
    floor = REGRESSION_FACTOR * committed

    from benchmarks import trajectory_recycle
    summary = trajectory_recycle.run(quick=quick)
    heat = summary["heat"]
    fresh = heat["lockstep_speedup"]
    syncs = heat["lockstep_syncs_per_cycle"]
    # optional key: artifacts/summaries written before the telemetry layer
    # landed don't carry it — treat absence as "not checked", not a failure
    util = heat.get("lockstep_utilization")

    mode = "quick" if quick else "full"
    print(f"[check_regression] heat lockstep_speedup ({mode} mode): "
          f"fresh {fresh:.3f}x vs committed {committed:.3f}x "
          f"(floor {floor:.3f}x)")
    print(f"[check_regression] lockstep host syncs/cycle: {syncs:.2f} "
          f"(budget {SYNC_BUDGET:g})")
    if util is not None:
        print(f"[check_regression] lockstep row utilization: {util:.2f} "
              f"(floor {UTILIZATION_FLOOR:g})")

    ok = True
    if fresh < floor:
        print("[check_regression] FAIL: lockstep speedup regressed below "
              f"{REGRESSION_FACTOR:.0%} of the committed baseline")
        ok = False
    if syncs > SYNC_BUDGET:
        print("[check_regression] FAIL: lockstep cycle loop exceeds "
              "1 blocking host sync per cycle")
        ok = False
    if util is not None and util < UTILIZATION_FLOOR:
        print("[check_regression] FAIL: lockstep row utilization fell "
              f"below {UTILIZATION_FLOOR:g} — padding creep in the chunk "
              "packing")
        ok = False
    if ok:
        print("[check_regression] OK")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
