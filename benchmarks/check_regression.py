"""CI perf ratchet for the lockstep engine.

Compares a FRESH quick run of `trajectory_recycle` against the committed
`results/BENCH_trajectory_recycle.json` artifact (the per-PR perf record):
the heat-family lockstep-vs-chunked-sequential wall-time ratio must stay
within REGRESSION_FACTOR of the committed value, and the lockstep engine
must hold its ≤ 1 blocking host sync per cycle budget. A PR that slows the
device-resident cycle path back toward host-mediated dispatch overhead
fails CI here instead of shipping as an unnoticed wall-time regression.

The committed baseline is read BEFORE the fresh run (the bench harness
overwrites the same artifact path), so this module must be the one to
launch the bench — run it stand-alone:

    PYTHONPATH=src python -m benchmarks.check_regression
"""
from __future__ import annotations

import json
import os
import sys

BASELINE = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "BENCH_trajectory_recycle.json")

# CI runners are noisy shared VMs: allow the ratio to dip to 75% of the
# committed value before calling it a regression (same slack philosophy as
# the coverage ratchet — tight enough to catch a host-boundary reintroduction
# splitting the cycle back into many dispatches, loose enough for jitter).
REGRESSION_FACTOR = 0.75
SYNC_BUDGET = 1.0  # blocking host fetches per lockstep cycle (inside loop)


def main() -> int:
    with open(BASELINE) as f:
        committed = json.load(f)["metrics"]["heat"]["lockstep_speedup"]
    floor = REGRESSION_FACTOR * committed

    from benchmarks import trajectory_recycle
    summary = trajectory_recycle.run(quick=True)
    heat = summary["heat"]
    fresh = heat["lockstep_speedup"]
    syncs = heat["lockstep_syncs_per_cycle"]

    print(f"[check_regression] heat lockstep_speedup: fresh {fresh:.3f}x "
          f"vs committed {committed:.3f}x (floor {floor:.3f}x)")
    print(f"[check_regression] lockstep host syncs/cycle: {syncs:.2f} "
          f"(budget {SYNC_BUDGET:g})")

    ok = True
    if fresh < floor:
        print("[check_regression] FAIL: lockstep speedup regressed below "
              f"{REGRESSION_FACTOR:.0%} of the committed baseline")
        ok = False
    if syncs > SYNC_BUDGET:
        print("[check_regression] FAIL: lockstep cycle loop exceeds "
              "1 blocking host sync per cycle")
        ok = False
    if ok:
        print("[check_regression] OK")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
