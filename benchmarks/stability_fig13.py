"""Paper Fig. 13: stability — fraction of systems hitting the max-iteration
cap without converging, per solver, under a tight cap (the paper uses 1e4 on
n=1e4 Darcy; we scale the cap down with the grid)."""
from __future__ import annotations

from benchmarks.common import CSV, run_sequence

NX = 24
NUM = 16
CAP = 450          # tight cap so GMRES visibly saturates on hard systems
TOLS = (1e-5, 1e-8)


def run(quick: bool = False):
    tols = TOLS[:1] if quick else TOLS
    num = 8 if quick else NUM
    csv = CSV(["tol", "solver", "hit_maxiter", "num", "fraction"])
    for tol in tols:
        for solver in ("gmres", "skr"):
            _, r = run_sequence("darcy", nx=NX, num=num, tol=tol,
                                precond="none", solver=solver,
                                maxiter=CAP)
            csv.row(f"{tol:g}", solver, r.hit_maxiter, r.num,
                    f"{r.hit_maxiter / r.num:.2f}")
    csv.emit(f"Fig 13 — stability under maxiter cap {CAP} "
             "(lower fraction = more stable; SKR should dominate)")


if __name__ == "__main__":
    run()
