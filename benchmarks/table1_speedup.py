"""Paper Table 1: SKR vs GMRES — computation-time and iteration speedup
ratios across {dataset × preconditioner × tolerance}.

CPU-scaled grids (paper ran n up to 71k on a 72-thread Xeon); the reproduced
quantity is the ratio table. TPU-adapted preconditioner set (DESIGN §4.6):
rbsor stands in for SOR, ilu_host for ILU."""
from __future__ import annotations

from benchmarks.common import CSV, run_sequence

# (family, nx, tolerances) — tol ladders follow the paper's per-dataset rows
DATASETS = [
    ("darcy", 32, (1e-2, 1e-5, 1e-8)),
    ("thermal", 32, (1e-5, 1e-8, 1e-11)),
    ("poisson", 32, (1e-5, 1e-8, 1e-11)),
    ("helmholtz", 32, (1e-2, 1e-5, 1e-7)),
]
PRECONDS = ("none", "jacobi", "bjacobi", "rbsor", "ilu_host")
NUM = 16


def run(quick: bool = False):
    datasets = DATASETS[:2] if quick else DATASETS
    preconds = PRECONDS[:2] if quick else PRECONDS
    csv = CSV(["dataset", "n", "precond", "tol", "gmres_ms", "skr_ms",
               "gmres_iters", "skr_iters", "time_speedup", "iter_speedup"])
    for fam, nx, tols in datasets:
        for pre in preconds:
            for tol in (tols[:1] if quick else tols):
                _, g = run_sequence(fam, nx=nx, num=NUM, tol=tol,
                                    precond=pre, solver="gmres")
                _, s = run_sequence(fam, nx=nx, num=NUM, tol=tol,
                                    precond=pre, solver="skr")
                csv.row(fam, nx * nx, pre, f"{tol:g}",
                        f"{g.mean_time_s * 1e3:.2f}",
                        f"{s.mean_time_s * 1e3:.2f}",
                        f"{g.mean_iters:.1f}", f"{s.mean_iters:.1f}",
                        f"{g.mean_time_s / max(s.mean_time_s, 1e-12):.2f}",
                        f"{g.mean_iters / max(s.mean_iters, 1e-9):.2f}")
    csv.emit("Table 1 — SKR vs GMRES speedups "
             "(time ratio / iteration ratio, >1 = SKR better)")


if __name__ == "__main__":
    run()
