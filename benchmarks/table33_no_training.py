"""Paper App. E.3 (Table 33): dataset validity — FNO trained on the SKR-
generated dataset vs the GMRES-generated dataset shows identical training
dynamics (relative-L2 at epochs)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CSV
from repro.core.skr import SKRConfig, generate_dataset, \
    generate_dataset_baseline
from repro.operators import FNOConfig, fno_apply, fno_init
from repro.operators.fno import add_coords, relative_l2
from repro.pde.registry import get_family
from repro.solvers.types import KrylovConfig
from repro.train.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig

NX = 20
NUM = 24
STEPS = 120
CHECK = (0, 30, 60, 90, 119)


def run(quick: bool = False):
    num = 12 if quick else NUM
    steps = 40 if quick else STEPS
    checks = [c for c in CHECK if c < steps] + [steps - 1]
    kc = KrylovConfig(m=30, k=10, tol=1e-8, maxiter=10_000)
    fam = get_family("darcy", nx=NX, ny=NX)
    key = jax.random.PRNGKey(0)
    ds = {
        "SKR": generate_dataset(fam, key, num,
                                SKRConfig(krylov=kc, precond="jacobi")),
        "GMRES": generate_dataset_baseline(fam, key, num, kc,
                                           precond="jacobi"),
    }
    cfg = FNOConfig(modes=6, width=16, n_blocks=2)
    csv = CSV(["dataset"] + [f"step{c}" for c in sorted(set(checks))])
    for name, d in ds.items():
        params = fno_init(jax.random.PRNGKey(1), cfg)
        x = add_coords(jnp.asarray(d.inputs))
        y = jnp.asarray(d.solutions)[..., None]
        scale = jnp.maximum(jnp.std(y), 1e-9)

        hist = {}

        def loss_fn(p, batch):
            pred = fno_apply(p, cfg, batch["x"])
            return jnp.mean((pred - batch["y"]) ** 2)

        tr = Trainer(loss_fn, params, optimizer=adamw(2e-3),
                     cfg=TrainerConfig(log_every=0))

        def batches(i):
            return {"x": x, "y": y / scale}

        state, losses = tr.run(batches, steps)
        rel = relative_l2(fno_apply(state["params"], cfg, x) * scale, y)
        vals = [f"{losses[c]:.4f}" for c in sorted(set(checks))]
        csv.row(name, *vals)
        print(f"{name}: final relative-L2 {float(rel):.4f}")
    csv.emit("Table 33 — FNO training on SKR vs GMRES data "
             "(identical dynamics expected)")


if __name__ == "__main__":
    run()
