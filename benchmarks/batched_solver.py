"""Batched vs per-system chunked SKR datagen (the tentpole speedup).

Both engines run the SAME App. E.2.2 decomposition — sort once, split into
B chunks, one recycle carry per chunk. The sequential engine dispatches tiny
device programs one system at a time; the batched engine advances all B
chunks in lockstep (one vmapped device program per cycle row), amortizing
dispatch + host round-trip latency across the batch. Reported: wall-clock
for the whole dataset, per-system averages, and the batched speedup.

A third set of rows runs the batched engine with the mixed-precision
policy (`inner_dtype="float32"`: fp32 inner cycles under an fp64
iterative-refinement outer loop — see benchmarks/mixed_precision.py for
the dedicated accuracy/throughput sweep) so the datagen-level speedup of
the precision axis is tracked next to the engine speedup.

Run:  PYTHONPATH=src python -m benchmarks.batched_solver [--quick]
"""
from __future__ import annotations

import dataclasses
import time

import jax

from benchmarks.common import CSV
from repro.core.skr import SKRConfig, generate_dataset_chunked
from repro.pde.registry import get_family
from repro.solvers.types import KrylovConfig

NX = 20
NUM = 32
TOL = 1e-6
FAMILIES = ("poisson", "darcy")
BATCHES = (4, 8)


def _timed_run(fam, num, cfg, workers, engine):
    # warmup pass compiles every jitted dispatch for this (engine, B) cell
    generate_dataset_chunked(fam, jax.random.PRNGKey(999), num, cfg,
                             workers=workers, engine=engine)
    t0 = time.perf_counter()
    chunks = generate_dataset_chunked(fam, jax.random.PRNGKey(0), num, cfg,
                                      workers=workers, engine=engine)
    wall = time.perf_counter() - t0
    iters = sum(c.stats.total_iterations for c in chunks) / num
    conv = sum(c.stats.num_converged for c in chunks)
    return wall, iters, conv


def run(quick: bool = False):
    num = 16 if quick else NUM
    batches = (4,) if quick else BATCHES
    kc = KrylovConfig(m=30, k=10, tol=TOL, maxiter=10_000)
    cfg = SKRConfig(krylov=kc, sort_method="greedy", precond="jacobi")
    cfg32 = dataclasses.replace(
        cfg, krylov=dataclasses.replace(kc, inner_dtype="float32"))
    csv = CSV(["family", "B", "engine", "wall_s", "per_system_ms",
               "mean_iters", "converged", "speedup_vs_seq"])

    wins = []
    for family in FAMILIES:
        fam = get_family(family, nx=NX, ny=NX)
        for b in batches:
            ws, its, cs = _timed_run(fam, num, cfg, b, "sequential")
            wb, itb, cb = _timed_run(fam, num, cfg, b, "batched")
            w32, it32, c32 = _timed_run(fam, num, cfg32, b, "batched")
            csv.row(family, b, "sequential", f"{ws:.3f}",
                    f"{1e3 * ws / num:.2f}", f"{its:.1f}", cs, "-")
            csv.row(family, b, "batched", f"{wb:.3f}",
                    f"{1e3 * wb / num:.2f}", f"{itb:.1f}", cb,
                    f"{ws / wb:.2f}x")
            csv.row(family, b, "batched-fp32", f"{w32:.3f}",
                    f"{1e3 * w32 / num:.2f}", f"{it32:.1f}", c32,
                    f"{ws / w32:.2f}x")
            wins.append((family, b, ws / wb, wb / w32))
    csv.emit("Batched lockstep vs per-system chunked SKR datagen "
             f"(grid {NX}x{NX}, {num} systems, tol {TOL:g})")
    for family, b, speedup, sp32 in wins:
        flag = "OK" if speedup > 1.0 else "SLOWER"
        print(f"  {family} B={b}: batched {speedup:.2f}x [{flag}], "
              f"fp32-inner a further {sp32:.2f}x over batched-f64")
    return wins


if __name__ == "__main__":
    import sys

    run(quick="--quick" in sys.argv)
