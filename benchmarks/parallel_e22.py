"""Paper App. E.2.2 (Table 31): chunk-parallel SKR — sort once, split the
sorted sequence into W worker chunks, each with its own recycle carry.
Reported: per-system iteration/time averages vs single-worker GMRES and the
parallel-latency estimate (max over chunks), for BOTH chunk engines:
  sequential — chunks run back-to-back (the paper-parity simulation)
  batched    — chunks advance in lockstep through BatchedGCRODRSolver, so
               the latency estimate is a measured wall clock, not a max
               over simulated chunk times."""
from __future__ import annotations

import time

import jax

from benchmarks.common import CSV, run_sequence
from repro.core.skr import SKRConfig, generate_dataset_chunked
from repro.pde.registry import get_family
from repro.solvers.types import KrylovConfig

NX = 20
NUM = 24
TOL = 1e-5


def run(quick: bool = False):
    num = 12 if quick else NUM
    workers = (1, 4) if quick else (1, 2, 4, 8)
    fam = get_family("helmholtz", nx=NX, ny=NX)
    kc = KrylovConfig(m=30, k=10, tol=TOL, maxiter=10_000)
    csv = CSV(["variant", "engine", "workers", "mean_iters", "mean_time_s",
               "parallel_latency_est_s"])

    _, g = run_sequence("helmholtz", nx=NX, num=num, tol=TOL,
                        precond="rbsor", solver="gmres")
    csv.row("GMRES", "-", 1, f"{g.mean_iters:.1f}", f"{g.mean_time_s:.4f}",
            "-")

    cfg = SKRConfig(krylov=kc, sort_method="greedy", precond="rbsor")
    for engine in ("sequential", "batched"):
        for w in workers:
            if engine == "batched" and w == 1:
                continue  # w=1 always routes sequentially
            # warmup: compile every jitted dispatch for this (engine, w) cell
            generate_dataset_chunked(fam, jax.random.PRNGKey(999),
                                     max(2 * w, 4), cfg, workers=w,
                                     engine=engine)
            t0 = time.perf_counter()
            chunks = generate_dataset_chunked(fam, jax.random.PRNGKey(0),
                                              num, cfg, workers=w,
                                              engine=engine)
            wall = time.perf_counter() - t0
            iters = sum(c.stats.total_iterations for c in chunks) / num
            # sequential: latency estimate = slowest simulated chunk;
            # batched: per-system wall times are the shared lockstep clock,
            # so the LONGEST chunk carries one entry per lockstep row
            latency = max(c.stats.total_time_s for c in chunks)
            csv.row("SKR", engine, w, f"{iters:.1f}", f"{wall / num:.4f}",
                    f"{latency:.3f}")
    csv.emit("App E.2.2 — chunk-parallel SKR (sequential: latency = slowest "
             "simulated chunk; batched: measured lockstep wall clock)")


if __name__ == "__main__":
    run()
