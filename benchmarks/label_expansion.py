"""Few-solves-many-labels: DiffOAS-style batched label expansion.

Measures labels/second as the expansion factor K sweeps off → 2 → 8, on
both pipeline shapes: steady SKR datagen (poisson, darcy; lockstep batched
engine) and time-dependent trajectory datagen (heat; lockstep engine,
per-snapshot expansion under A(t)). Every expanded label costs one GRF
perturbation slot plus one row of a single strided batched SpMV (f' = A u')
riding on the already device-resident operator stacks, so labels/s should
scale nearly linearly with K+1 while solves/s stays flat — the headline
`k8_ratio` per family is (labels/s at K=8) / (labels/s expansion-off), and
the win condition is ≥ 5x on at least two families.

Full mode also runs the FNO quality gates (examples/train_fno.py and
examples/train_fno_rollout.py): at EQUAL label count, an FNO trained on
expanded labels must land within 10% held-out relative-L2 of one trained
on all-solved labels (ratio ≤ 1.10). Throughput without that gate would
be a vacuous win — manufactured labels are only cheap if they are worth
training on.

Run:  PYTHONPATH=src python -m benchmarks.label_expansion [--quick]
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import CSV
from repro.core.expand import ExpandConfig
from repro.core.skr import SKRConfig, generate_dataset_chunked
from repro.core.trajectory import TrajConfig, generate_trajectories_chunked
from repro.pde.registry import get_family, get_timedep_family
from repro.solvers.types import KrylovConfig

NX = 20
NUM = 32
NT = 6
TOL = 1e-8
KS = (2, 8)
WORKERS = 4
STEADY = ("poisson", "darcy")
# quality-gate operating points (full mode): matched label count across
# arms, distribution-matched perturbations (grf_alpha = forcing alpha + 2,
# amplitude ~ 1, Dirichlet taper on — see the gate docstrings for the
# sweeps). The rollout gate runs at k=1: heat's conditioning channel is
# per-sample operator diversity, which expansion cannot manufacture, so
# its quality crossover sits at much lower K than the shared-operator
# steady gate (k=7).
GATE = dict(num=96, k=7, steps=400, nx=16, amplitude=1.0,
            grf_alpha=4.5, grf_tau=7.0)
ROLLOUT_GATE = dict(num=48, k=1, steps=400, nx=16, nt=8, amplitude=1.0,
                    grf_alpha=4.5, grf_tau=7.0)
QUALITY_LIMIT = 1.10
WIN_RATIO = 5.0


def _timed(gen, fam, num, cfg, workers):
    """Warmup (compiles every jitted dispatch incl. the expansion wave's
    perturb + strided-SpMV programs), then one timed pass."""
    gen(fam, jax.random.PRNGKey(999), num, cfg,
        workers=workers, engine="batched")
    t0 = time.perf_counter()
    chunks = gen(fam, jax.random.PRNGKey(0), num, cfg,
                 workers=workers, engine="batched")
    return time.perf_counter() - t0, chunks


def _labels(chunks, base_per_item, num):
    """Shipped label count: the expanded LabelSet when expansion is on,
    else the solved labels the pipeline already emits (1 per steady
    system, nt snapshots per trajectory)."""
    n = sum(len(c.labels) for c in chunks if c.labels is not None)
    return n if n else num * base_per_item


def _sweep(name, gen, fam, num, cfg_for, base_per_item, workers, csv):
    out = {}
    wall0, chunks = _timed(gen, fam, num, cfg_for(None), workers)
    n0 = _labels(chunks, base_per_item, num)
    lps0 = n0 / wall0
    out["off"] = {"labels": n0, "wall_s": round(wall0, 3),
                  "labels_per_second": round(lps0, 1)}
    csv.row(name, "off", num, n0, f"{wall0:.3f}", f"{lps0:.1f}", "-")
    for k in KS:
        wall, chunks = _timed(gen, fam, num, cfg_for(k), workers)
        n = _labels(chunks, base_per_item, num)
        lps = n / wall
        out[f"k{k}"] = {"labels": n, "wall_s": round(wall, 3),
                        "labels_per_second": round(lps, 1),
                        "ratio_vs_off": round(lps / lps0, 2)}
        csv.row(name, f"k={k}", num, n, f"{wall:.3f}", f"{lps:.1f}",
                f"{lps / lps0:.2f}x")
    out["k8_ratio"] = out["k8"]["ratio_vs_off"]
    return out


def run(quick: bool = False, gates=None):
    """`gates` overrides whether the FNO quality gates run (default: full
    mode only). check_regression.py passes gates=False so the throughput
    ratchet can re-measure in the committed artifact's mode without
    re-training four FNOs per CI run."""
    run_gates = (not quick) if gates is None else bool(gates)
    num = 16 if quick else NUM
    nx = 16 if quick else NX
    nt = 4 if quick else NT
    kc = KrylovConfig(m=30, k=10, tol=TOL, maxiter=10_000)
    csv = CSV(["family", "expand", "solves", "labels", "wall_s",
               "labels_per_s", "ratio_vs_off"])
    metrics = {}

    for family in STEADY:
        fam = get_family(family, nx=nx, ny=nx)

        def cfg_for(k):
            return SKRConfig(
                krylov=kc, sort_method="greedy", precond="jacobi",
                expand=None if k is None else ExpandConfig(k=k))

        metrics[family] = _sweep(family, generate_dataset_chunked, fam,
                                 num, cfg_for, 1, WORKERS, csv)

    fam = get_timedep_family("heat", nx=nx, ny=nx, nt=nt)

    def cfg_for(k):
        return TrajConfig(
            krylov=kc, sort_method="greedy", precond="jacobi",
            expand=None if k is None else ExpandConfig(k=k))

    metrics["heat"] = _sweep("heat", generate_trajectories_chunked, fam,
                             num, cfg_for, nt, WORKERS, csv)

    csv.emit(f"Label expansion throughput (grid {nx}x{nx}, {num} "
             f"solves/trajectories, lockstep engine, tol {TOL:g})")

    wins = [f for f in metrics if metrics[f]["k8_ratio"] >= WIN_RATIO]
    metrics["families_ge_5x"] = len(wins)
    for f in sorted(metrics):
        if isinstance(metrics[f], dict):
            r = metrics[f]["k8_ratio"]
            flag = "OK" if r >= WIN_RATIO else "BELOW"
            print(f"  {f}: K=8 labels/s ratio {r:.2f}x [{flag}]")

    quality_ok = True
    if run_gates:
        # quality gates: expanded labels must train within 10% of all-solved
        from examples.train_fno import run_fno_expansion_gate
        from examples.train_fno_rollout import run_rollout_expansion_gate

        print("\n  FNO quality gate (steady, poisson):")
        gate = run_fno_expansion_gate(**GATE)
        print("  FNO quality gate (rollout, heat):")
        rgate = run_rollout_expansion_gate(**ROLLOUT_GATE)
        metrics["fno_gate"] = gate
        metrics["rollout_gate"] = rgate
        quality_ok = (gate["ratio"] <= QUALITY_LIMIT
                      and rgate["ratio"] <= QUALITY_LIMIT)
        for tag, g in (("steady", gate), ("rollout", rgate)):
            flag = "OK" if g["ratio"] <= QUALITY_LIMIT else "FAIL"
            print(f"  {tag} gate: expanded/solved error ratio "
                  f"{g['ratio']:.3f} (limit {QUALITY_LIMIT}) [{flag}]")

    metrics["ok"] = bool(len(wins) >= 2 and quality_ok)
    print(f"\n  label_expansion: {len(wins)}/{len(STEADY) + 1} families "
          f">= {WIN_RATIO:g}x at K=8; quality "
          f"{'ok' if quality_ok else 'FAILED'} -> "
          f"{'OK' if metrics['ok'] else 'FAIL'}")
    return metrics


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    run(quick=args.quick)
