"""Cross-PR benchmark trend report.

`benchmarks/run.py` writes one machine-readable ``results/BENCH_<name>.json``
artifact per bench (name, wall time, quick flag, headline metrics). This
module folds EVERY artifact currently in ``results/`` into a single
``results/TREND.md`` — a summary table plus per-bench metric dumps — so the
perf trajectory is reviewable in-repo PR over PR (the artifacts are
committed; CI regenerates the report and uploads both as build artifacts).

Run:  PYTHONPATH=src python -m benchmarks.trend [--results-dir DIR]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")


def _headline(metrics) -> str:
    """Best-effort one-line summary of a bench's metrics payload."""
    if isinstance(metrics, dict):
        if "best_speedup" in metrics:
            return f"best speedup {metrics['best_speedup']}x"
        per_key = {k: v["speedup"] for k, v in metrics.items()
                   if isinstance(v, dict) and "speedup" in v}
        if per_key:
            return ", ".join(f"{k} {v}x" for k, v in sorted(per_key.items()))
        scalars = {k: v for k, v in metrics.items()
                   if isinstance(v, (int, float, str, bool))}
        if scalars:
            return ", ".join(f"{k}={v}" for k, v in
                             sorted(scalars.items())[:4])
        return f"{len(metrics)} metric groups"
    if isinstance(metrics, list):
        return f"{len(metrics)} rows"
    return str(metrics)[:60] if metrics is not None else "-"


def load_artifacts(results_dir: str) -> tuple[list[dict], list[tuple[str, str]]]:
    """Parse every BENCH_*.json; unparseable or malformed artifacts (e.g.
    truncated by an interrupted writer) are SKIPPED with a warning on
    stderr — one bad file must never take down the whole trend report.
    Returns (good artifacts, [(skipped file, short reason)]) so the report
    keeps a visible one-line trace of what was dropped."""
    arts, skipped = [], []
    for path in sorted(glob.glob(os.path.join(results_dir, "BENCH_*.json"))):
        name = os.path.basename(path)
        try:
            with open(path) as f:
                art = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"[trend] WARNING: skipping unparseable artifact "
                  f"{name}: {e}", file=sys.stderr)
            skipped.append((name, "unparseable JSON"))
            continue
        if not isinstance(art, dict):
            print(f"[trend] WARNING: skipping malformed artifact "
                  f"{name}: expected a JSON object, got "
                  f"{type(art).__name__}", file=sys.stderr)
            skipped.append((name, f"not a JSON object ({type(art).__name__})"))
            continue
        art["_file"] = name
        arts.append(art)
    return arts, skipped


def render(arts: list[dict], skipped: list[tuple[str, str]] = ()) -> str:
    lines = [
        "# Benchmark trend",
        "",
        "Folded from the committed `results/BENCH_<name>.json` artifacts "
        "(one per bench, refreshed by `python -m benchmarks.run`; this file "
        "by `python -m benchmarks.trend`). Wall times are per-box numbers — "
        "the tracked quantities across PRs are the RATIOS.",
        "",
        "| bench | mode | wall_s | headline |",
        "| --- | --- | --- | --- |",
    ]
    for art in arts:
        mode = "quick" if art.get("quick") else "full"
        lines.append(f"| {art.get('name', '?')} | {mode} | "
                     f"{art.get('wall_s', '-')} | "
                     f"{_headline(art.get('metrics'))} |")
    # dropped artifacts stay visible as one-line rows (their metrics are
    # untrusted, so only the fact and the reason are reported)
    for name, reason in skipped:
        lines.append(f"| {name} | - | - | SKIPPED: {reason} |")
    lines.append("")
    for art in arts:
        lines.append(f"## {art.get('name', '?')}")
        lines.append("")
        # optional blocks (artifacts written before these existed lack
        # them — absence is fine)
        prov = art.get("provenance")
        if isinstance(prov, dict):
            bits = [f"`{prov['git_sha'][:12]}`" if prov.get("git_sha")
                    else None,
                    prov.get("timestamp"),
                    (f"jax {prov['jax_version']}"
                     if prov.get("jax_version") else None),
                    (f"{prov['device_count']}x {prov['device_kind']}"
                     if prov.get("device_kind") else None)]
            lines.append("Provenance: " + " · ".join(b for b in bits if b))
            lines.append("")
        tele = art.get("telemetry")
        if isinstance(tele, dict) and tele.get("utilization") is not None:
            lines.append(f"Telemetry: lockstep utilization "
                         f"{100 * tele['utilization']:.1f}%")
            lines.append("")
        lines.append("```json")
        lines.append(json.dumps(art.get("metrics"), indent=2, sort_keys=True))
        lines.append("```")
        lines.append("")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--results-dir", default=RESULTS_DIR)
    args = ap.parse_args(argv)
    arts, skipped = load_artifacts(args.results_dir)
    if not arts and not skipped:
        print(f"no BENCH_*.json artifacts under {args.results_dir}")
        return 1
    out = os.path.join(args.results_dir, "TREND.md")
    tmp = out + ".tmp"
    with open(tmp, "w") as f:
        f.write(render(arts, skipped))
        f.write("\n")
    os.replace(tmp, out)
    note = f", {len(skipped)} skipped" if skipped else ""
    print(f"[trend: {os.path.relpath(out)} — {len(arts)} benches{note}]")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
