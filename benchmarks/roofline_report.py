"""§Roofline report: aggregates the dry-run JSONs (results/dryrun_pod,
results/dryrun_multipod) into the per-(arch × shape × mesh) roofline table —
three terms, dominant bottleneck, MODEL_FLOPS ratio, HBM fit."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import CSV

DIRS = ("results/dryrun_pod", "results/dryrun_multipod")


def load_records(dirs=DIRS):
    recs = []
    for d in dirs:
        for fn in sorted(glob.glob(os.path.join(d, "*.json"))):
            with open(fn) as f:
                recs.append(json.load(f))
    return recs


def run(quick: bool = False):
    recs = load_records()
    if not recs:
        print("### Roofline report: no dry-run results found "
              "(run python -m repro.launch.dryrun --all first)")
        return
    csv = CSV(["arch", "shape", "mesh", "status", "compute_s", "memory_s",
               "collective_s", "dominant", "useful_flops",
               "bytes_per_chip_GB", "fits_16GB"])
    for r in recs:
        if r["status"] != "ok":
            csv.row(r["arch"], r["shape"], r["mesh"], r["status"],
                    "-", "-", "-", "-", "-", "-", "-")
            continue
        roof = r["roofline"]
        csv.row(r["arch"], r["shape"], r["mesh"], "ok",
                f"{roof['compute_s']:.3e}", f"{roof['memory_s']:.3e}",
                f"{roof['collective_s']:.3e}", roof["dominant"],
                f"{(roof['useful_flops_ratio'] or 0):.2f}",
                f"{r.get('bytes_per_chip', 0) / 1e9:.1f}",
                r.get("fits_v5e_hbm"))
    csv.emit("Roofline — per (arch × shape × mesh) from the compiled dry-run")

    ok = [r for r in recs if r["status"] == "ok"]
    by_dom = {}
    for r in ok:
        by_dom.setdefault(r["roofline"]["dominant"], []).append(r)
    print("\ndominant-term census:",
          {k: len(v) for k, v in sorted(by_dom.items())})


if __name__ == "__main__":
    run()
