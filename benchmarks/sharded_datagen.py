"""Multi-device sharded datagen: per-device throughput scaling.

Runs the unified pipeline's `sharded` engine (chunk-chain axis of the
lockstep `BatchedGCRODRSolver` sharded over a 1-D `data` mesh) at device
counts 1/2/4/8 and reports dataset throughput for a steady family (poisson
systems) and a trajectory family (heat implicit steps). The device count is
fixed at JAX init, so each count runs in a SUBPROCESS with
`XLA_FLAGS=--xla_force_host_platform_device_count=N` — the same recipe the
CI multi-device smoke job and `tests/test_pipeline.py` use.

HONESTY NOTE: on this box the "devices" are VIRTUAL CPU devices sharing the
same physical cores, so the committed ratios measure what sharding COSTS
(SPMD partitioning + cross-device collectives + per-shard dispatch) at
fixed total compute, not real multi-chip speedup — near-flat throughput
across device counts is the success criterion here; real scaling needs one
accelerator per shard. The 1-device row is the plain batched engine (the
sharded engine degenerates to it when no mesh is available).

Run:  PYTHONPATH=src python -m benchmarks.sharded_datagen [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

DEVICE_COUNTS = (1, 2, 4, 8)
CHAINS = 8          # divides every device count above


def _worker(args) -> dict:
    """One measurement at the CURRENT process's device count."""
    import jax

    from repro.core.skr import SKRConfig, generate_dataset_chunked
    from repro.core.trajectory import (TrajConfig,
                                       generate_trajectories_chunked)
    from repro.pde.registry import get_family, get_timedep_family
    from repro.solvers.types import KrylovConfig

    kc = KrylovConfig(m=30, k=10, tol=1e-6, maxiter=10_000)
    out = {"devices": len(jax.devices())}

    fam = get_family("poisson", nx=args.nx, ny=args.nx)
    cfg = SKRConfig(krylov=kc, sort_method="greedy", precond="jacobi")
    generate_dataset_chunked(fam, jax.random.PRNGKey(999), args.num, cfg,
                             workers=CHAINS, engine="sharded")  # warmup
    t0 = time.perf_counter()
    chunks = generate_dataset_chunked(fam, jax.random.PRNGKey(0), args.num,
                                      cfg, workers=CHAINS, engine="sharded")
    wall = time.perf_counter() - t0
    out["poisson_wall_s"] = round(wall, 3)
    out["poisson_systems_per_s"] = round(args.num / wall, 2)
    out["poisson_converged"] = int(sum(c.stats.num_converged for c in chunks))

    tfam = get_timedep_family("heat", nx=args.nx, ny=args.nx, nt=args.nt,
                              dt=5e-2)
    tcfg = TrajConfig(krylov=kc, sort_method="greedy", precond="jacobi")
    generate_trajectories_chunked(tfam, jax.random.PRNGKey(999), args.ntraj,
                                  tcfg, workers=CHAINS, engine="sharded")
    t0 = time.perf_counter()
    tchunks = generate_trajectories_chunked(tfam, jax.random.PRNGKey(0),
                                            args.ntraj, tcfg, workers=CHAINS,
                                            engine="sharded")
    wall = time.perf_counter() - t0
    steps = args.ntraj * args.nt
    out["heat_wall_s"] = round(wall, 3)
    out["heat_steps_per_s"] = round(steps / wall, 2)
    out["heat_converged"] = int(sum(c.stats.num_converged for c in tchunks))
    return out


def _spawn(ndev: int, quick: bool, extra_args: list[str]) -> dict:
    env = dict(os.environ)
    # the sweep's device count goes LAST: XLA gives the last duplicate flag
    # precedence, so an inherited --xla_force_host_platform_device_count in
    # the caller's XLA_FLAGS must not override the row being measured
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={ndev}"
                        ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    cmd = [sys.executable, "-m", "benchmarks.sharded_datagen", "--worker"]
    if quick:
        cmd.append("--quick")
    cmd += extra_args
    out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                         timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(f"worker (devices={ndev}) failed:\n"
                           f"{out.stderr[-2000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(quick: bool = False):
    from benchmarks.common import CSV

    rows = {}
    for ndev in DEVICE_COUNTS:
        rows[ndev] = _spawn(ndev, quick, [])
    base = rows[DEVICE_COUNTS[0]]

    csv = CSV(["devices", "poisson_wall_s", "poisson_systems_per_s",
               "heat_wall_s", "heat_steps_per_s", "vs_1dev_poisson",
               "vs_1dev_heat"])
    for ndev, r in rows.items():
        csv.row(ndev, r["poisson_wall_s"], r["poisson_systems_per_s"],
                r["heat_wall_s"], r["heat_steps_per_s"],
                f"{r['poisson_systems_per_s'] / base['poisson_systems_per_s']:.2f}x",
                f"{r['heat_steps_per_s'] / base['heat_steps_per_s']:.2f}x")
    csv.emit("Sharded datagen throughput vs virtual-CPU device count "
             f"({CHAINS} chunk chains; 1-device row = plain batched engine)")
    print("  NOTE: virtual devices share the same physical cores — these "
          "ratios track sharding OVERHEAD at fixed compute, not multi-chip "
          "speedup.")

    return {
        "chains": CHAINS,
        "note": ("virtual CPU devices share physical cores: ratios measure "
                 "SPMD sharding overhead at fixed total compute; near-flat "
                 "is good, real scaling needs one accelerator per shard"),
        "per_devices": {str(k): v for k, v in rows.items()},
        "scaling_vs_1dev": {
            "poisson": {str(k): round(v["poisson_systems_per_s"]
                                      / base["poisson_systems_per_s"], 3)
                        for k, v in rows.items()},
            "heat": {str(k): round(v["heat_steps_per_s"]
                                   / base["heat_steps_per_s"], 3)
                     for k, v in rows.items()},
        },
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--worker", action="store_true",
                    help="internal: measure at THIS process's device count "
                         "and print one JSON line")
    ap.add_argument("--nx", type=int, default=None)
    ap.add_argument("--num", type=int, default=None)
    ap.add_argument("--ntraj", type=int, default=None)
    ap.add_argument("--nt", type=int, default=None)
    args = ap.parse_args(argv)

    if args.nx is None:
        args.nx = 16 if args.quick else 24
    if args.num is None:
        args.num = 16 if args.quick else 32
    if args.ntraj is None:
        args.ntraj = 8
    if args.nt is None:
        args.nt = 4 if args.quick else 6

    if args.worker:
        print(json.dumps(_worker(args)))
        return 0
    run(quick=args.quick)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
