"""Benchmark harness — one module per paper table/figure:

  table1_speedup       Table 1  (SKR vs GMRES, dataset × precond × tol)
  table2_sort_ablation Table 2  (sort ablation + δ metric)
  convergence_fig11    Fig 11/12 (accuracy-vs-cost ladders + slope fits)
  stability_fig13      Fig 13   (max-iteration saturation fractions)
  parallel_e22         Table 31 (chunk-parallel SKR, both engines)
  batched_solver       lockstep batched vs per-system chunked datagen
  mixed_precision      fp32-inner + fp64 refinement vs fp64 baseline
                       (precision-policy tentpole; lockstep engine)
  trajectory_recycle   time-dependent stepping: recycled vs cold-start
                       (heat, convdiff-t, wave M≠I), sequential vs lockstep
                       engines, adaptive-Δt step counts vs fixed
  sharded_datagen      multi-device sharded pipeline: per-device throughput
                       at 1/2/4/8 virtual CPU devices (subprocess sweep)
  table33_no_training  Table 33 (FNO on SKR vs GMRES data)
  label_expansion      few-solves-many-labels: labels/s vs expansion K
                       (DiffOAS f' = A u' waves; poisson/darcy/heat) +
                       FNO quality gates at equal label count (full mode)
  streaming_datagen    online streaming scheduler: mid-flight slot refill
                       vs wave-padding baseline on Poisson traces
                       (utilization, p50/p99 latency, label parity)
  roofline_report      §Roofline (aggregates dry-run artifacts)

Each run also writes a machine-readable ``results/BENCH_<name>.json``
artifact (name, wall time, headline metrics = whatever the bench's ``run``
returns, plus a ``provenance`` block — git SHA, timestamp, jax/jaxlib
versions, device kind/count — so a committed artifact is traceable to the
box and tree that produced it) so the perf trajectory is tracked across
PRs.

``--telemetry`` runs every bench under the observability layer
(``repro.obs``): each artifact gains a ``telemetry`` block (lockstep
utilization, occupancy counters) and the trace exports
``results/TRACE_<name>.json`` (Chrome/Perfetto — load in
chrome://tracing) + ``results/TELEMETRY_<name>.jsonl``.

``python -m benchmarks.run [--quick] [--only NAME] [--telemetry]``
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import tempfile
import time

from benchmarks import (batched_solver, convergence_fig11, label_expansion,
                        mixed_precision, parallel_e22, roofline_report,
                        sharded_datagen, stability_fig13, streaming_datagen,
                        table1_speedup, table2_sort_ablation,
                        table33_no_training, trajectory_recycle)

BENCHES = [
    ("table1_speedup", table1_speedup.run),
    ("table2_sort_ablation", table2_sort_ablation.run),
    ("convergence_fig11", convergence_fig11.run),
    ("stability_fig13", stability_fig13.run),
    ("parallel_e22", parallel_e22.run),
    ("batched_solver", batched_solver.run),
    ("mixed_precision", mixed_precision.run),
    ("trajectory_recycle", trajectory_recycle.run),
    ("sharded_datagen", sharded_datagen.run),
    ("table33_no_training", table33_no_training.run),
    ("label_expansion", label_expansion.run),
    ("streaming_datagen", streaming_datagen.run),
    ("roofline_report", roofline_report.run),
]

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")


def _provenance() -> dict:
    """Run provenance stamped into every artifact: enough to trace a
    committed BENCH_*.json back to the tree and box that produced it.
    Consumers (trend.py, check_regression.py) treat the block as optional —
    artifacts written before it existed keep loading."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        sha = ""
    out = {"git_sha": sha or "unknown",
           "timestamp": datetime.datetime.now(
               datetime.timezone.utc).isoformat(timespec="seconds")}
    try:
        import jax
        import jaxlib

        devs = jax.devices()
        out.update(jax_version=jax.__version__,
                   jaxlib_version=jaxlib.__version__,
                   device_kind=devs[0].device_kind if devs else "none",
                   device_count=len(devs),
                   platform=devs[0].platform if devs else "none")
    except Exception:  # provenance must never take down a bench run
        pass
    return out


def _jsonable(obj):
    """Best-effort conversion of a bench's return value to JSON types."""
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if hasattr(obj, "item"):  # numpy scalars
        return obj.item()
    if hasattr(obj, "tolist"):  # numpy arrays
        return obj.tolist()
    return str(obj)


def _write_artifact(name: str, wall_s: float, quick: bool, metrics,
                    provenance=None, telemetry=None):
    """Atomic artifact publish: write to a UNIQUE tmp file in results/ (same
    filesystem), then `os.replace`. A fixed tmp name would let two
    concurrent runs of the same bench interleave writes and publish a
    truncated JSON; mkstemp gives every writer its own file and the rename
    is atomic, so `benchmarks/trend.py` never sees a half-written artifact."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"BENCH_{name}.json")
    fd, tmp = tempfile.mkstemp(dir=RESULTS_DIR, prefix=f"BENCH_{name}.",
                               suffix=".tmp")
    try:
        os.fchmod(fd, 0o644)  # mkstemp defaults to 0600; keep artifacts
        doc = {"name": name, "wall_s": round(wall_s, 3),
               "quick": quick, "metrics": _jsonable(metrics)}
        if provenance:
            doc["provenance"] = _jsonable(provenance)
        if telemetry:
            doc["telemetry"] = _jsonable(telemetry)
        with os.fdopen(fd, "w") as f:  # world-readable like plain open()
            json.dump(doc, f, indent=2)
            f.write("\n")
        os.replace(tmp, path)  # atomic publish
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    print(f"[artifact: {os.path.relpath(path)}]")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced grids/tols for CI-speed runs")
    ap.add_argument("--only", default=None,
                    choices=[n for n, _ in BENCHES])
    ap.add_argument("--no-artifacts", action="store_true",
                    help="skip writing results/BENCH_<name>.json")
    ap.add_argument("--telemetry", action="store_true",
                    help="run under repro.obs: telemetry block per "
                         "artifact + results/TRACE_<name>.json / "
                         "TELEMETRY_<name>.jsonl exports")
    args = ap.parse_args(argv)

    prov = _provenance()
    failed = []
    for name, fn in BENCHES:
        if args.only and name != args.only:
            continue
        if args.telemetry:
            from repro import obs
            obs.enable()   # fresh buffers per bench
        t0 = time.perf_counter()
        print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}")
        metrics = fn(quick=args.quick)
        wall = time.perf_counter() - t0
        print(f"[{name}: {wall:.1f}s]")
        telemetry = None
        if args.telemetry:
            from repro import obs
            telemetry = obs.summary()
            if not args.no_artifacts:
                os.makedirs(RESULTS_DIR, exist_ok=True)
                trace = os.path.join(RESULTS_DIR, f"TRACE_{name}.json")
                jsonl = os.path.join(RESULTS_DIR, f"TELEMETRY_{name}.jsonl")
                obs.export_chrome_trace(trace)
                obs.export_jsonl(jsonl)
                print(f"[trace: {os.path.relpath(trace)}]")
            obs.disable()
        if not args.no_artifacts:
            _write_artifact(name, wall, args.quick, metrics,
                            provenance=prov, telemetry=telemetry)
        # benches may publish an acceptance verdict under metrics["ok"]
        # (e.g. mixed_precision's speedup/accuracy gate) — propagate it so
        # CI's quick-verify job actually fails on a regression
        if isinstance(metrics, dict) and metrics.get("ok") is False:
            failed.append(name)
    if failed:
        print(f"\nFAILED acceptance gates: {', '.join(failed)}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
