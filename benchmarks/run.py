"""Benchmark harness — one module per paper table/figure:

  table1_speedup       Table 1  (SKR vs GMRES, dataset × precond × tol)
  table2_sort_ablation Table 2  (sort ablation + δ metric)
  convergence_fig11    Fig 11/12 (accuracy-vs-cost ladders + slope fits)
  stability_fig13      Fig 13   (max-iteration saturation fractions)
  parallel_e22         Table 31 (chunk-parallel SKR, both engines)
  batched_solver       lockstep batched vs per-system chunked datagen
  table33_no_training  Table 33 (FNO on SKR vs GMRES data)
  roofline_report      §Roofline (aggregates dry-run artifacts)

``python -m benchmarks.run [--quick] [--only NAME]``
"""
from __future__ import annotations

import argparse
import time

from benchmarks import (batched_solver, convergence_fig11, parallel_e22,
                        roofline_report, stability_fig13, table1_speedup,
                        table2_sort_ablation, table33_no_training)

BENCHES = [
    ("table1_speedup", table1_speedup.run),
    ("table2_sort_ablation", table2_sort_ablation.run),
    ("convergence_fig11", convergence_fig11.run),
    ("stability_fig13", stability_fig13.run),
    ("parallel_e22", parallel_e22.run),
    ("batched_solver", batched_solver.run),
    ("table33_no_training", table33_no_training.run),
    ("roofline_report", roofline_report.run),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced grids/tols for CI-speed runs")
    ap.add_argument("--only", default=None,
                    choices=[n for n, _ in BENCHES])
    args = ap.parse_args(argv)

    for name, fn in BENCHES:
        if args.only and name != args.only:
            continue
        t0 = time.perf_counter()
        print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}")
        fn(quick=args.quick)
        print(f"[{name}: {time.perf_counter() - t0:.1f}s]")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
