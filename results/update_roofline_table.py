"""Regenerate the §Roofline table inside EXPERIMENTS.md from the dry-run
JSONs (run after a dry-run refresh)."""
import glob
import json
import os
import re

rows = []
for d in ("results/dryrun_pod", "results/dryrun_multipod"):
    for fn in sorted(glob.glob(os.path.join(d, "*.json"))):
        rows.append(json.load(open(fn)))
order = {"pod16x16": 0, "pods2x16x16": 1}
rows.sort(key=lambda r: (order.get(r["mesh"], 2), r["arch"], r["shape"]))

lines = ["| arch | shape | mesh | status | compute_s | memory_s | "
         "collective_s | dominant | 6ND/HLO | GB/chip |",
         "|---|---|---|---|---|---|---|---|---|---|"]
for r in rows:
    if r["status"] != "ok":
        lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                     f"{r['status']} | – | – | – | – | – | – |")
        continue
    ro = r["roofline"]
    lines.append(
        f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
        f"{ro['compute_s']:.2e} | {ro['memory_s']:.2e} | "
        f"{ro['collective_s']:.2e} | **{ro['dominant']}** | "
        f"{ro['useful_flops_ratio'] or 0:.2f} | "
        f"{r.get('bytes_per_chip', 0) / 1e9:.1f} |")
table = "\n".join(lines)

exp = open("EXPERIMENTS.md").read()
start = exp.index("| arch | shape | mesh |")
end = exp.index("\n\nDominant-term census")
open("EXPERIMENTS.md", "w").write(exp[:start] + table + exp[end:])
print(f"updated table with {len(rows)} rows")
